"""Paper Figs. 13/17: K-ring topology built by DGRO vs six baselines.

Baselines: random K-ring, all-nearest K-ring, Chord, RAPID, Perigee(+ring),
GA — every topology comes from the ``repro.overlay`` builder registry, so a
new baseline is one ``@overlay.register`` away.  DGRO here = the registry's
``"dgro"`` builder, the paper's full pipeline at benchmark scale: adaptive
mixed rings via rho-selection, best of several candidate mixes scored in one
batched device call (the trained DQN covers n<=~50 in fig10; this sweep runs
to n=300+ where the paper itself falls back to heuristic construction, §V).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro import overlay
from repro.core.construction import default_num_rings, k_rings
from repro.core.ga import GAConfig
from repro.core.topology import make_latency


def run(dist: str = "uniform", sizes=(50, 100, 200), ga_budget: int = 300,
        seed: int = 0):
    t0 = time.time()
    print("n,dgro,random,nearest,chord,rapid,perigee,ga")
    wins = 0
    for n in sizes:
        w = make_latency(dist, n, seed=seed + n)
        k = max(2, default_num_rings(n) // 2)
        rng = np.random.default_rng(seed)
        dgro = overlay.build("dgro", w, overlay.DGROConfig(k=k), rng=rng)
        d_dgro = dgro.diameter()
        d_rand = overlay.Overlay.from_rings(
            w, k_rings(w, k, "random", rng)).diameter()
        d_near = overlay.Overlay.from_rings(
            w, k_rings(w, k, "nearest", rng)).diameter()
        d_chord = overlay.build("chord", w, rng=rng).diameter()
        d_rapid = overlay.build("rapid", w, overlay.RapidConfig(k=k),
                                rng=rng).diameter()
        d_peri = overlay.build("perigee", w, rng=rng).diameter()
        d_ga = overlay.build("ga", w, GAConfig(k_rings=k, budget=ga_budget,
                                               seed=seed)).diameter()
        print(f"{n},{d_dgro:.1f},{d_rand:.1f},{d_near:.1f},{d_chord:.1f},"
              f"{d_rapid:.1f},{d_peri:.1f},{d_ga:.1f}")
        if d_dgro <= min(d_rand, d_near) + 1e-9:
            wins += 1
    wall = time.time() - t0
    print(f"# dist={dist}: DGRO best-of-ring-family in {wins}/{len(sizes)} sizes")
    return {"name": f"fig13_kring_compare[{dist}]",
            "us_per_call": wall * 1e6 / len(sizes),
            "derived": f"dgro<=min(random,nearest) in {wins}/{len(sizes)}",
            "wins": wins == len(sizes)}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dist", default="uniform")
    ap.add_argument("--sizes", type=int, nargs="+", default=[50, 100, 200])
    ap.add_argument("--ga-budget", type=int, default=300)
    args = ap.parse_args()
    run(args.dist, tuple(args.sizes), args.ga_budget)
