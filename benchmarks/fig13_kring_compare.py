"""Paper Figs. 13/17: K-ring topology built by DGRO vs six baselines.

Baselines: random K-ring, all-nearest K-ring, Chord, RAPID, Perigee(+ring),
GA.  DGRO here = the paper's full pipeline at benchmark scale: adaptive
mixed rings via rho-selection, best of several candidate mixes (the trained
DQN covers n<=~50 in fig10; this sweep runs to n=300+ where the paper itself
falls back to heuristic construction, §V).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import protocols
from repro.core.construction import default_num_rings, k_rings
from repro.core.diameter import adjacency_from_rings, diameter_scipy
from repro.core.ga import GAConfig, ga_search
from repro.core.selection import (clustering_ratio, measure_latency_stats,
                                  select_ring_kind)
from repro.core.topology import make_latency


def dgro_adaptive(w, k, rng, n_candidates: int = 4):
    """rho-guided mixed-ring construction: measure rho on a probe overlay,
    shortlist M values near the indicated regime, keep the best diameter."""
    n = w.shape[0]
    probe = adjacency_from_rings(w, k_rings(w, k, "random", rng))
    rho = clustering_ratio(measure_latency_stats(w, probe, seed=0))
    kind = select_ring_kind(rho)
    if kind == "nearest":      # too random -> mostly nearest rings
        ms = range(0, min(2, k) + 1)
    elif kind == "random":     # too clustered -> mostly random rings
        ms = range(max(0, k - 2), k + 1)
    else:
        ms = range(0, k + 1, max(1, k // n_candidates))
    best = np.inf
    for m in ms:
        rings = k_rings(w, k, f"mixed:{m}", rng)
        d = diameter_scipy(adjacency_from_rings(w, rings))
        best = min(best, d)
    return best, rho


def run(dist: str = "uniform", sizes=(50, 100, 200), ga_budget: int = 300,
        seed: int = 0):
    t0 = time.time()
    print("n,dgro,random,nearest,chord,rapid,perigee,ga")
    wins = 0
    for n in sizes:
        w = make_latency(dist, n, seed=seed + n)
        k = max(2, default_num_rings(n) // 2)
        rng = np.random.default_rng(seed)
        d_dgro, rho = dgro_adaptive(w, k, rng)
        d_rand = diameter_scipy(adjacency_from_rings(w, k_rings(w, k, "random", rng)))
        d_near = diameter_scipy(adjacency_from_rings(w, k_rings(w, k, "nearest", rng)))
        d_chord = diameter_scipy(protocols.chord(w, rng)[0])
        d_rapid = diameter_scipy(protocols.rapid(w, rng, k)[0])
        d_peri = diameter_scipy(protocols.perigee(w, rng)[0])
        _, d_ga, _ = ga_search(w, GAConfig(k_rings=k, budget=ga_budget, seed=seed))
        print(f"{n},{d_dgro:.1f},{d_rand:.1f},{d_near:.1f},{d_chord:.1f},"
              f"{d_rapid:.1f},{d_peri:.1f},{d_ga:.1f}")
        if d_dgro <= min(d_rand, d_near) + 1e-9:
            wins += 1
    wall = time.time() - t0
    print(f"# dist={dist}: DGRO best-of-ring-family in {wins}/{len(sizes)} sizes")
    return {"name": f"fig13_kring_compare[{dist}]",
            "us_per_call": wall * 1e6 / len(sizes),
            "derived": f"dgro<=min(random,nearest) in {wins}/{len(sizes)}",
            "wins": wins == len(sizes)}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dist", default="uniform")
    ap.add_argument("--sizes", type=int, nargs="+", default=[50, 100, 200])
    ap.add_argument("--ga-budget", type=int, default=300)
    args = ap.parse_args()
    run(args.dist, tuple(args.sizes), args.ga_budget)
