"""Paper Fig. 9: DQN training/test curve (diameter vs epoch) + rollout gate.

Two parts:

* **Training curve** — trains the DQN through the device rollout engine
  (``repro.core.rollout``: one fused ``lax.scan`` device call per epoch)
  and asserts the paper's qualitative claim: the test diameter improves as
  training progresses and ends below the random ring.  Reduced defaults
  for CPU (paper: N up to 200, 1e4 epochs); pass --epochs / --n for the
  full sweep.

* **Rollout throughput gate** — greedy K-ring construction over
  ``bench_envs`` graphs of ``bench_n`` nodes, device engine (ONE vmapped
  scan call) vs the step-by-step host episode loop it replaced (one device
  round-trip per action + full APSP per reward).  The acceptance gate is
  >= 10x rollout steps/sec for the device engine at N=32, E=8 on CPU
  (enforced by ``benchmarks.run`` via ``passes_gate``).

Results land in ``BENCH_fig09_dqn.json`` (uploaded by the CI benchmarks
job) so the perf trajectory is archived across PRs.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import overlay
from repro.core import rollout
from repro.core.embedding import init_qparams
from repro.core.qlearning import DQNConfig, construct_ring_dqn, train_dqn
from repro.core.topology import make_latency


def _bench_rollout(bench_n: int, bench_envs: int, k_rings: int, seed: int,
                   dist: str, device_reps: int = 10, trials: int = 3) -> dict:
    """Rollout steps/sec: fused device engine vs host episode loop.

    Both paths report best-of-``trials`` (min wall time) — the same
    noise-mitigation fig16 uses; single short timing windows on shared CPU
    runners are bimodal enough to flip the gate otherwise."""
    cfg = DQNConfig(n=bench_n, k_rings=k_rings, seed=seed, dist=dist)
    params = init_qparams(jax.random.PRNGKey(seed), cfg.p, cfg.h)
    ws = np.stack([make_latency(dist, bench_n, seed=40_000 + i)
                   for i in range(bench_envs)])
    steps = bench_envs * k_rings * bench_n

    plan = rollout.make_plan(np.random.default_rng(seed), bench_envs,
                             k_rings, bench_n)
    args = (jnp.asarray(ws, jnp.float32), jnp.asarray(plan.starts),
            jnp.asarray(plan.eps_u), jnp.asarray(plan.choice_u))

    def device_call():
        return rollout.rollout_episodes(
            params, *args, 0.0, cfg.alpha, k_rings=k_rings,
            n_rounds=cfg.n_rounds)[2].block_until_ready()

    t0 = time.perf_counter()
    device_call()                                   # compile + warm
    compile_s = time.perf_counter() - t0
    best_dev = float("inf")
    for _ in range(trials):
        t0 = time.perf_counter()
        for _ in range(device_reps):
            device_call()
        best_dev = min(best_dev, time.perf_counter() - t0)
    device_sps = device_reps * steps / best_dev

    hcfg = dataclasses.replace(cfg, rollout="host")
    construct_ring_dqn(params, hcfg, ws[0], np.random.default_rng(seed))
    best_host = float("inf")
    for _ in range(trials):
        t0 = time.perf_counter()
        for e in range(bench_envs):
            construct_ring_dqn(params, hcfg, ws[e],
                               np.random.default_rng(seed + e))
        best_host = min(best_host, time.perf_counter() - t0)
    host_sps = steps / best_host

    return {
        "n": bench_n, "envs": bench_envs, "k_rings": k_rings,
        "steps_per_call": steps,
        "rollout_steps_per_s_device": device_sps,
        "rollout_steps_per_s_host": host_sps,
        "speedup": device_sps / host_sps,
        "device_compile_s": compile_s,
    }


def run(n: int = 14, epochs: int = 120, k_rings: int = 2, seed: int = 0,
        dist: str = "uniform", eval_graphs: int = 5, n_envs: int = 1,
        rollout_mode: str = "device", bench_n: int = 32, bench_envs: int = 8,
        out_json: str = "BENCH_fig09_dqn.json"):
    cfg = DQNConfig(n=n, k_rings=k_rings, epochs=epochs,
                    eps_decay=max(epochs // 2, 1), seed=seed, dist=dist,
                    rollout=rollout_mode, n_envs=n_envs)
    t0 = time.time()
    params, log = train_dqn(cfg, eval_every=max(epochs // 8, 1),
                            eval_graphs=eval_graphs)
    train_s = time.time() - t0

    rng = np.random.default_rng(seed)
    rand_d = np.mean([
        overlay.build("random", make_latency(dist, n, seed=10_000 + i),
                      overlay.RandomRingsConfig(k=k_rings),
                      rng=rng).diameter()
        for i in range(3)])

    print("epoch,train_diam,test_diam,loss")
    for e, tr, te, lo in zip(log.epochs, log.train_diam, log.test_diam, log.loss):
        print(f"{e},{tr:.2f},{te:.2f},{lo:.4f}")
    first, last = log.test_diam[0], log.test_diam[-1]
    best = min(log.test_diam)
    print(f"# random_ring_diam={rand_d:.2f} first={first:.2f} last={last:.2f} "
          f"best={best:.2f} train_s={train_s:.1f} "
          f"train_steps_per_s={log.steps_per_sec:.0f} [{cfg.rollout}]")

    bench = _bench_rollout(bench_n, bench_envs, k_rings, seed, dist)
    print(f"# rollout N={bench['n']} E={bench['envs']}: "
          f"device {bench['rollout_steps_per_s_device']:.0f} steps/s vs "
          f"host {bench['rollout_steps_per_s_host']:.0f} steps/s "
          f"-> {bench['speedup']:.1f}x (gate >= 10x)")

    results = {
        "train": {
            "n": n, "epochs": epochs, "k_rings": k_rings, "dist": dist,
            "rollout": cfg.rollout, "n_envs": n_envs,
            "seconds": log.seconds, "train_steps_per_s": log.steps_per_sec,
            "test_diam_first": first, "test_diam_last": last,
            "test_diam_best": best, "random_ring_diam": float(rand_d),
            "epochs_logged": log.epochs, "test_diam": log.test_diam,
        },
        "rollout_gate": bench,
    }
    with open(out_json, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)

    return {"name": "fig09_training_curve",
            "us_per_call": train_s * 1e6 / max(epochs, 1),
            "derived": f"test_diam {first:.1f}->best {best:.1f} "
                       f"(random {rand_d:.1f}); rollout "
                       f"{bench['speedup']:.1f}x device vs host",
            "improved": best <= first and best <= rand_d,
            "passes_gate": bench["speedup"] >= 10.0}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=14)
    ap.add_argument("--epochs", type=int, default=120)
    ap.add_argument("--k-rings", type=int, default=2)
    ap.add_argument("--dist", default="uniform")
    ap.add_argument("--n-envs", type=int, default=1)
    ap.add_argument("--rollout", default="device", choices=["device", "host"])
    ap.add_argument("--bench-n", type=int, default=32)
    ap.add_argument("--bench-envs", type=int, default=8)
    args = ap.parse_args()
    run(args.n, args.epochs, args.k_rings, dist=args.dist,
        n_envs=args.n_envs, rollout_mode=args.rollout,
        bench_n=args.bench_n, bench_envs=args.bench_envs)
