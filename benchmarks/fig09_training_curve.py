"""Paper Fig. 9: DQN training/test curve (diameter vs epoch).

Reduced defaults for CPU (paper: N up to 200, 1e4 epochs); pass --epochs /
--n for the full sweep.  Asserts the paper's qualitative claim: the test
diameter improves as training progresses and ends below the random ring.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro import overlay
from repro.core.qlearning import DQNConfig, train_dqn
from repro.core.topology import make_latency


def run(n: int = 14, epochs: int = 120, k_rings: int = 2, seed: int = 0,
        dist: str = "uniform", eval_graphs: int = 5):
    cfg = DQNConfig(n=n, k_rings=k_rings, epochs=epochs,
                    eps_decay=max(epochs // 2, 1), seed=seed, dist=dist)
    t0 = time.time()
    params, log = train_dqn(cfg, eval_every=max(epochs // 8, 1),
                            eval_graphs=eval_graphs)
    train_s = time.time() - t0

    rng = np.random.default_rng(seed)
    rand_d = np.mean([
        overlay.build("random", make_latency(dist, n, seed=10_000 + i),
                      overlay.RandomRingsConfig(k=k_rings),
                      rng=rng).diameter()
        for i in range(3)])

    print("epoch,train_diam,test_diam,loss")
    for e, tr, te, lo in zip(log.epochs, log.train_diam, log.test_diam, log.loss):
        print(f"{e},{tr:.2f},{te:.2f},{lo:.4f}")
    first, last = log.test_diam[0], log.test_diam[-1]
    best = min(log.test_diam)
    print(f"# random_ring_diam={rand_d:.2f} first={first:.2f} last={last:.2f} "
          f"best={best:.2f} train_s={train_s:.1f}")
    return {"name": "fig09_training_curve",
            "us_per_call": train_s * 1e6 / max(epochs, 1),
            "derived": f"test_diam {first:.1f}->best {best:.1f} (random {rand_d:.1f})",
            "improved": best <= first and best <= rand_d}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=14)
    ap.add_argument("--epochs", type=int, default=120)
    ap.add_argument("--k-rings", type=int, default=2)
    ap.add_argument("--dist", default="uniform")
    args = ap.parse_args()
    run(args.n, args.epochs, args.k_rings, dist=args.dist)
