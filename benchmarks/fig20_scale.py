"""APSP/diameter scaling curve: the streamed engine at N=4096+.

Sweeps graph size N at a fixed candidate batch B and measures end-to-end
diameter throughput through the streaming facade
(``batcheval.diameters_of_rings``: chunked assembly -> chunked APSP ->
host reduction), recording for every cell the facts the memory model
claims: resolved method, chunk, modeled peak working-set bytes, device
call count, and the process high-water mark (``ru_maxrss``).

Three HARD gates (all at CI-affordable sizes, enforced by
``benchmarks.run``):

  * **bit parity** — the streamed facade (small chunks, padded trailing
    block) returns EXACTLY the same bits as one direct
    ``batched_diameter`` call over the whole stack — the pre-engine code
    path — at N <= 256 (``np.array_equal``, no tolerance);
  * **tiled parity** — the blocked (tiled) Floyd-Warshall method agrees
    with the auto method to float32 round-off on the same stack;
  * **memory bound** — at the largest swept N the modeled working set is
    a fraction of the dense (B, N, N) stack (the facade streams; it never
    materializes the batch), and the streamed chunk is smaller than B.

Reduced-precision evaluation (bfloat16 compute, int16-quantized
latencies) is measured against the exact float32 result and reported
informationally in the JSON artifact.

The acceptance cell — B=64 at N=4096 on a single CPU host — is the
default ``__main__`` invocation:

    PYTHONPATH=src python -m benchmarks.fig20_scale --ns 256 1024 4096 --b 64
"""
from __future__ import annotations

import argparse
import json
import resource
import time

import numpy as np

from repro.core import batcheval
from repro.core.topology import make_latency


def _genomes(rng, n: int, b: int, k_rings: int = 2) -> np.ndarray:
    return np.stack([[rng.permutation(n) for _ in range(k_rings)]
                     for _ in range(b)])


def _gates(n: int, b: int, seed: int) -> dict:
    """Parity + memory gates at a small, always-affordable size."""
    rng = np.random.default_rng(seed)
    # gaussian: continuous weights, so the reduced-precision errors below
    # are real (integer-valued worlds sum exactly in bf16)
    w = make_latency("gaussian", n, seed=seed + n)
    genomes = _genomes(rng, n, b)
    adjs = batcheval.adjacency_batch_from_rings(w, genomes)

    # the pre-engine path: one jit'd batched_diameter over the whole stack
    ref = np.asarray(batcheval.batched_diameter(adjs))
    one_shot = np.asarray(batcheval.diameters(adjs))
    streamed = np.asarray(batcheval.diameters(adjs, chunk=max(1, b // 4)))
    from_rings = np.asarray(batcheval.diameters_of_rings(
        w, genomes, chunk=max(1, b // 4)))
    parity = (np.array_equal(ref, one_shot)
              and np.array_equal(ref, streamed)
              and np.array_equal(ref, from_rings))

    tiled = np.asarray(batcheval.diameters(adjs, method="tiled"))
    tiled_ok = bool(np.allclose(ref, tiled, rtol=1e-5, atol=1e-4))

    # memory boundedness, forced: a budget worth ~4 matrices of temporaries
    # must make the facade stream (chunk < B), stay inside the modeled
    # working set, and STILL return the exact same bits
    budget = 4 * n * n * 4 * 8
    with batcheval.eval_options(budget_bytes=budget):
        under_budget = np.asarray(batcheval.diameters(adjs))
    rep = batcheval.last_eval_report()
    budget_ok = (rep["chunk"] < b and rep["workingset_bytes"] <= budget
                 and np.array_equal(ref, under_budget))

    # reduced precision, measured against the exact result (informational)
    bf16 = np.asarray(batcheval.diameters(adjs, dtype="bfloat16"))
    bf16_err = float(np.max(np.abs(bf16 - ref) / np.maximum(ref, 1e-9)))
    bf16_rep = batcheval.last_eval_report()
    i16 = np.asarray(batcheval.diameters(adjs, dtype="int16"))
    i16_err = float(np.max(np.abs(i16 - ref) / np.maximum(ref, 1e-9)))
    i16_rep = batcheval.last_eval_report()

    return {
        "parity_n": n, "parity_b": b,
        "parity_bitexact": bool(parity),
        "tiled_allclose": tiled_ok,
        "budget_streaming_ok": bool(budget_ok),
        "budget_bytes_forced": budget,
        "budget_chunk": rep["chunk"],
        "bf16_max_rel_err": bf16_err,
        "bf16_fallback": bool(bf16_rep.get("fallback")),
        "int16_max_rel_err": i16_err,
        "int16_fallback": bool(i16_rep.get("fallback")),
    }


def _cell(n: int, b: int, seed: int, b_cap: int | None) -> dict:
    """One scaling cell: streamed diameters over B ring genomes at size N."""
    rng = np.random.default_rng(seed + n)
    w = make_latency("uniform", n, seed=seed + n)
    b_timed = b if (b_cap is None or n < 2048) else min(b, b_cap)
    genomes = _genomes(rng, n, b_timed)
    if n <= 1024:                              # warm the jit cache; at larger
        batcheval.diameters_of_rings(w, genomes[:1])   # N one pass is the run
    t0 = time.perf_counter()
    out = batcheval.diameters_of_rings(w, genomes)
    dt = time.perf_counter() - t0
    rep = batcheval.last_eval_report()
    assert np.all(np.isfinite(out)), f"non-finite diameter at N={n}"
    return {
        "n": n, "b": b, "b_timed": b_timed,
        "seconds": dt * (b / b_timed),
        "diam_per_s": b_timed / dt,
        "method": rep.get("method"),
        "chunk": rep.get("chunk"),
        "device_calls": rep.get("device_calls"),
        "workingset_bytes": rep.get("workingset_bytes"),
        "dense_stack_bytes": int(b) * n * n * 4,
        "ru_maxrss_mb": resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
        // 1024,
    }


def run(ns=(256, 1024, 4096), b: int = 64, seed: int = 0,
        parity_n: int = 256, b_cap: int | None = None,
        out_json: str = "BENCH_fig20_scale.json"):
    """Returns the harness row; prints one CSV line per N cell.

    ``b_cap`` bounds how many candidates cells at N >= 2048 actually time
    (throughput extrapolated linearly) so the harness full sweep stays
    CI-affordable; the acceptance run passes ``b_cap=None``.
    """
    t0 = time.time()
    gate = _gates(min(parity_n, 256), min(b, 64), seed)
    print(f"# parity@N={gate['parity_n']}: "
          f"bitexact={gate['parity_bitexact']} "
          f"tiled={gate['tiled_allclose']} "
          f"bf16_err={gate['bf16_max_rel_err']:.2e} "
          f"int16_err={gate['int16_max_rel_err']:.2e}")

    print("N,B,diam_per_s,seconds,method,chunk,workingset_mb,dense_stack_mb,"
          "ru_maxrss_mb")
    cells = []
    for n in ns:
        c = _cell(n, b, seed, b_cap)
        cells.append(c)
        print(f"{c['n']},{c['b']},{c['diam_per_s']:.2f},{c['seconds']:.1f},"
              f"{c['method']},{c['chunk']},"
              f"{c['workingset_bytes'] / 2**20:.0f},"
              f"{c['dense_stack_bytes'] / 2**20:.0f},{c['ru_maxrss_mb']}")

    # when the top cell actually streams (chunk < B), its modeled working
    # set must be a fraction of the dense stack; when B fits one chunk the
    # forced-budget gate above already proved the streaming path
    top = cells[-1]
    streams = top["chunk"] < b
    mem_ok = gate["budget_streaming_ok"] and (
        not streams or top["workingset_bytes"] < top["dense_stack_bytes"] / 2)
    gate["largest_n"] = top["n"]
    gate["largest_n_diam_per_s"] = top["diam_per_s"]
    gate["memory_bounded"] = bool(mem_ok)

    results = {"gate": gate, "cells": cells, "b": b, "ns": list(ns)}
    with open(out_json, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)

    wall = time.time() - t0
    passes = (gate["parity_bitexact"] and gate["tiled_allclose"]
              and gate["memory_bounded"])
    return {"name": "fig20-scale",
            "us_per_call": wall * 1e6 / max(1, len(cells)),
            "derived": f"N={top['n']} B={b}: {top['diam_per_s']:.2f} diam/s, "
                       f"ws {top['workingset_bytes'] / 2**20:.0f}MB vs dense "
                       f"{top['dense_stack_bytes'] / 2**20:.0f}MB; "
                       f"parity={gate['parity_bitexact']}",
            "passes_gate": passes}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--ns", type=int, nargs="+", default=[256, 1024, 4096])
    ap.add_argument("--b", type=int, default=64)
    ap.add_argument("--b-cap", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out-json", default="BENCH_fig20_scale.json")
    args = ap.parse_args()
    print(run(tuple(args.ns), b=args.b, b_cap=args.b_cap, seed=args.seed,
              out_json=args.out_json))
