"""Fig. 17 (service): the live control plane under churn + drift.

Boots the :mod:`repro.service` daemon in-process (real HTTP over loopback),
streams a combined ``churn_with_drift`` trace at N=128 through the /v1
ingest API, and measures

* sustained ingest throughput (events/s through ``POST /v1/events``),
* query latency p50/p99 at rest, and
* query latency p99 WHILE a re-optimization cycle is in flight — the
  double-buffered swap must keep the read path answering.

Then it exercises the crash window: a re-optimization is forced to die
between the buffer swap and the snapshot commit, and the restarted state
must serve exactly the diameter recorded in the last COMMITTED snapshot.

Hard gate (enforced via ``benchmarks.run``'s registry): query p99 during
the in-flight re-optimization stays under ``p99_bound_ms`` AND the
post-restart diameter equals the pre-crash snapshot diameter.  Results land
in ``BENCH_fig17_service.json``.

    PYTHONPATH=src python -m benchmarks.fig17_service [--events 200]
"""
from __future__ import annotations

import argparse
import json
import shutil
import tempfile
import threading
import time

import numpy as np

from repro.dynamics.scenarios import Trace, churn_with_drift
from repro.service import (Reoptimizer, ServiceClient, ServiceError,
                           ServiceServer, ServiceState, latest_snapshot)


class _SimulatedCrash(RuntimeError):
    """Raised by the crash hook: dies after the swap, before the snapshot."""


def _percentile(samples, q):
    return float(np.percentile(np.asarray(samples), q)) if samples else float("nan")


def _query_round(client: ServiceClient, nodes, lat_ms) -> None:
    """One mixed query round; appends per-request latencies in ms."""
    for call in (client.stats,
                 (lambda: client.route(nodes[0], nodes[-1])) if len(nodes) >= 2
                 else client.stats,
                 client.diameter):
        t0 = time.perf_counter()
        try:
            call()
        except ServiceError:
            pass        # a routed node died mid-round; the answer still came
        lat_ms.append((time.perf_counter() - t0) * 1e3)


def run(events: int = 200, n0: int = 128, seed: int = 0,
        eps: float = 0.49, p99_bound_ms: float = 250.0,
        out_json: str = "BENCH_fig17_service.json"):
    trace = churn_with_drift(
        n0=n0, dist="bitnode", seed=seed, horizon=30_000.0,
        join_rate=events / 2 / 30_000.0, leave_rate=events / 2 / 30_000.0)
    evs = sorted(trace.events, key=lambda e: e.time)[:events]
    assert len(evs) >= events // 2, f"trace produced only {len(evs)} events"

    snapdir = tempfile.mkdtemp(prefix="dgro-fig17-")
    world = Trace(n0=n0, capacity=trace.capacity, dist="bitnode", seed=seed,
                  events=[], name="fig17")
    state = ServiceState.fresh(world, policy="dgro", snapshot_dir=snapdir,
                               seed=seed)
    server = ServiceServer(state, reopt_enabled=False).start()
    try:
        client = ServiceClient(server.url)
        client.wait_ready()

        # ---- part A: sustained ingest throughput + baseline latency ------
        lat_base = []
        t0 = time.perf_counter()
        for i in range(0, len(evs), 10):
            res = client.post_events(evs[i:i + 10])
            assert res["accepted"] > 0, res
            _query_round(client, client.adjacency()["nodes"], lat_base)
        ingest_s = time.perf_counter() - t0
        events_per_s = len(evs) / ingest_s
        n_live = client.stats()["n_live"]

        # ---- part B: query p99 while a re-optimization is in flight ------
        # the hook stretches the post-swap window so the read path is probed
        # inside it too, not just during the optimize phase
        reopt = Reoptimizer(state, every=2**31, eps=eps, seed=seed,
                            crash_hook=lambda: time.sleep(0.2))
        lat_reopt = []
        swapped = 0
        for attempt in range(5):
            worker = threading.Thread(target=reopt.step,
                                      kwargs={"force": True})
            nodes = client.adjacency()["nodes"]
            v0 = state.version
            worker.start()
            while worker.is_alive():
                _query_round(client, nodes, lat_reopt)
            worker.join()
            swapped += int(state.version > v0)
            if len(lat_reopt) >= 60 and swapped:
                break
        p99_reopt = _percentile(lat_reopt, 99)
    finally:
        server.stop(final_snapshot=False)

    # ---- part C: crash between swap and snapshot, then restart -----------
    state.write_snapshot(reason="bench-precrash")
    pre_seq, pre_payload = latest_snapshot(snapdir)
    crasher = Reoptimizer(
        state, every=2**31, eps=eps, seed=seed + 1,
        crash_hook=lambda: (_ for _ in ()).throw(_SimulatedCrash()))
    crashed = False
    for attempt in range(5):
        try:
            crasher.step(force=True)        # "keep" cycles don't reach the hook
        except _SimulatedCrash:
            crashed = True
            break
    post_seq, post_payload = latest_snapshot(snapdir)
    assert post_seq == pre_seq, "crash window leaked a snapshot"

    restored = ServiceState.restore(snapdir)
    restart_diam = restored.diameter(exact=True)["diameter"]
    snap_diam = post_payload["diameter"]
    restart_matches = abs(restart_diam - snap_diam) <= 1e-5 * max(1.0, snap_diam)

    p99_ok = np.isfinite(p99_reopt) and p99_reopt <= p99_bound_ms
    answered = len(lat_reopt)
    results = {
        "throughput": {"n0": n0, "events": len(evs),
                       "events_per_s": events_per_s, "n_live_end": n_live},
        "latency": {"baseline_p50_ms": _percentile(lat_base, 50),
                    "baseline_p99_ms": _percentile(lat_base, 99),
                    "during_reopt_p99_ms": p99_reopt,
                    "samples_during_reopt": answered,
                    "reopt_swaps": swapped},
        "gate": {"query_p99_ms_during_reopt": p99_reopt,
                 "p99_bound_ms": p99_bound_ms,
                 "queries_answered_during_reopt": answered,
                 "crash_injected": crashed,
                 "snapshot_diameter": snap_diam,
                 "restart_diameter": restart_diam,
                 "restart_matches_snapshot": restart_matches},
    }
    with open(out_json, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    shutil.rmtree(snapdir, ignore_errors=True)

    print("metric,value")
    print(f"events_per_s,{events_per_s:.0f}")
    print(f"baseline_p99_ms,{_percentile(lat_base, 99):.2f}")
    print(f"during_reopt_p99_ms,{p99_reopt:.2f}")
    print(f"restart_diameter,{restart_diam:.4f}")
    print(f"snapshot_diameter,{snap_diam:.4f}")
    return {"name": "fig17_service",
            "us_per_call": ingest_s * 1e6 / max(len(evs), 1),
            "derived": f"{events_per_s:.0f} ev/s; p99 {p99_reopt:.1f}ms "
                       f"during reopt ({answered} queries); restart diam "
                       f"{'==' if restart_matches else '!='} snapshot",
            "passes_gate": bool(p99_ok and answered > 0 and restart_matches)}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=200)
    ap.add_argument("--n0", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(events=args.events, n0=args.n0, seed=args.seed)
