"""Fig. 18 (observability): instrumentation must be near-free and honest.

Two claims are gated, both over a fig17-style churn + query workload:

* **overhead** — the fully-instrumented ingest/query path
  (``ServiceState`` with counters, gauges, lock-wait and span histograms
  armed) sustains throughput within ``overhead_bound_pct`` (default 5%) of
  the same path with the global registry disabled
  (``REGISTRY.set_enabled(False)`` — every record is one boolean check).
  Both modes run after a shared warmup so jit compiles are not billed to
  either side, and each mode takes its best of ``repeats`` runs.

* **accuracy** — scraped metrics agree with client-side ground truth over a
  real HTTP run: ``repro_service_events_ingested_total`` moves by EXACTLY
  the number of events streamed, per-endpoint request counters move by
  exactly the number of requests issued, and the fixed-bucket histogram's
  p99 estimate lands within the containing bucket's width of
  ``np.percentile`` over the same samples.

Results land in ``BENCH_fig18_obs.json`` (gated via ``benchmarks.run``).

    PYTHONPATH=src python -m benchmarks.fig18_obs [--events 120]
"""
from __future__ import annotations

import argparse
import gc
import json
import time
from typing import Dict, List, Sequence

import numpy as np

from repro.dynamics.scenarios import Event, Trace, churn_with_drift
from repro.obs import REGISTRY
from repro.obs.metrics import LATENCY_BUCKETS_S, Histogram
from repro.service import ServiceClient, ServiceServer, ServiceState


def _world(trace: Trace, name: str) -> Trace:
    return Trace(n0=trace.n0, capacity=trace.capacity, dist=trace.dist,
                 seed=trace.seed, events=[], name=name)


def _state_workload(world: Trace, evs: Sequence[Event], *, seed: int,
                    chunk: int = 10) -> List[float]:
    """One full ingest+query pass against a fresh ServiceState (no HTTP —
    loopback sockets would drown the instrumentation delta in syscall
    noise).  Returns PER-CHUNK wall times of the churn+query loop; the
    initial overlay build/APSP is excluded from both modes alike.

    Per-chunk times let the caller take elementwise minima across repeats:
    chunk i does identical work in every run, so a scheduler stall in one
    run perturbs only that run's sample for that chunk."""
    state = ServiceState.fresh(world, policy="dgro", seed=seed)
    out: List[float] = []
    for i in range(0, len(evs), chunk):
        t0 = time.perf_counter()
        state.ingest(evs[i:i + chunk])
        nodes = state.adjacency()["nodes"]
        state.stats()
        if len(nodes) >= 2:
            try:
                state.route(int(nodes[0]), int(nodes[-1]))
            except ValueError:
                pass        # a routed endpoint churned out mid-round
        state.diameter()
        out.append(time.perf_counter() - t0)
    return out


def _counter_delta(before: Dict, after: Dict, series: str, **labels) -> float:
    """Delta of one labelled sample between two parsed scrapes."""
    key = tuple(sorted(labels.items()))
    return (after.get(series, {}).get(key, 0.0)
            - before.get(series, {}).get(key, 0.0))


def _p99_tolerance(samples: np.ndarray, true_p99: float) -> float:
    """Width of the LATENCY_BUCKETS_S bucket containing ``true_p99`` — the
    histogram's stated resolution there.  Past the last bound the estimate
    is clamped to the observed max, so the slack is max - last_bound."""
    bounds = list(LATENCY_BUCKETS_S)
    if true_p99 > bounds[-1]:
        return float(samples.max()) - bounds[-1] + 1e-9
    hi = next(b for b in bounds if true_p99 <= b)
    lo = max([0.0] + [b for b in bounds if b < hi])
    return hi - lo + 1e-9


def run(events: int = 240, n0: int = 64, seed: int = 0, repeats: int = 4,
        overhead_bound_pct: float = 5.0,
        out_json: str = "BENCH_fig18_obs.json"):
    trace = churn_with_drift(
        n0=n0, dist="bitnode", seed=seed, horizon=30_000.0,
        join_rate=events / 2 / 30_000.0, leave_rate=events / 2 / 30_000.0)
    evs = sorted(trace.events, key=lambda e: e.time)[:events]
    assert len(evs) >= events // 2, f"trace produced only {len(evs)} events"

    # ---- part A: instrumented vs disabled throughput ---------------------
    # odd repeat counts round up: the A/B order alternation only balances
    # run positions (earlier runs are systematically slower) in pairs
    repeats += repeats % 2
    was_enabled = REGISTRY.enabled
    REGISTRY.set_enabled(True)
    _state_workload(_world(trace, "fig18-warmup"), evs, seed=seed)  # jit warm
    chunks: Dict[bool, List[List[float]]] = {False: [], True: []}
    try:
        gc.disable()                 # keep collection pauses out of the A/B
        for rep in range(repeats):
            # alternate A/B order per repeat so slow machine-wide drift
            # (thermal, background load) cannot bias one mode
            order = (False, True) if rep % 2 == 0 else (True, False)
            for enabled in order:
                REGISTRY.set_enabled(enabled)
                gc.collect()
                chunks[enabled].append(_state_workload(
                    _world(trace, "fig18-run"), evs, seed=seed))
    finally:
        gc.enable()
        REGISTRY.set_enabled(was_enabled)
    # elementwise best across repeats, then sum: each chunk's fastest
    # observation is its least-perturbed one
    t_off = float(np.sum(np.min(chunks[False], axis=0)))
    t_on = float(np.sum(np.min(chunks[True], axis=0)))
    times = {m: [float(np.sum(r)) for r in chunks[m]] for m in (False, True)}
    overhead_pct = (t_on - t_off) / t_off * 100.0
    overhead_ok = overhead_pct <= overhead_bound_pct
    ev_per_s_on = len(evs) / t_on

    # ---- part B: scraped counters vs client-side ground truth (HTTP) ----
    state = ServiceState.fresh(_world(trace, "fig18-http"), policy="dgro",
                               seed=seed)
    server = ServiceServer(state, reopt_enabled=False).start()
    try:
        client = ServiceClient(server.url)
        client.wait_ready()
        before = client.metrics()
        sent = batches = stats_calls = 0
        stats_lat_s: List[float] = []
        for i in range(0, len(evs), 10):
            chunk = evs[i:i + 10]
            res = client.post_events(chunk)
            assert res["accepted"] == len(chunk), res
            sent += len(chunk)
            batches += 1
            t0 = time.perf_counter()
            client.stats()
            stats_lat_s.append(time.perf_counter() - t0)
            stats_calls += 1
        after = client.metrics()
    finally:
        server.stop(final_snapshot=False)

    d_events = _counter_delta(before, after,
                              "repro_service_events_ingested_total")
    d_post = _counter_delta(before, after, "repro_http_requests_total",
                            method="POST", endpoint="events", status="200")
    d_stats = _counter_delta(before, after, "repro_http_requests_total",
                             method="GET", endpoint="stats", status="200")
    counts_ok = (d_events == sent and d_post == batches
                 and d_stats == stats_calls)

    # ---- part C: histogram p99 vs numpy over the same samples ------------
    lat = np.asarray(stats_lat_s)
    hist = Histogram("fig18_stats_latency_seconds", buckets=LATENCY_BUCKETS_S)
    for s in stats_lat_s:
        hist.observe(float(s))
    true_p99 = float(np.percentile(lat, 99))
    est_p99 = hist.quantile(0.99)
    tol = _p99_tolerance(lat, true_p99)
    p99_ok = abs(est_p99 - true_p99) <= tol

    results = {
        "overhead": {"n0": n0, "events": len(evs), "repeats": repeats,
                     "disabled_s": t_off, "enabled_s": t_on,
                     "events_per_s_enabled": ev_per_s_on,
                     "disabled_runs_s": times[False],
                     "enabled_runs_s": times[True]},
        "accuracy": {"events_sent": sent, "events_scraped": d_events,
                     "post_batches": batches, "post_requests_scraped": d_post,
                     "stats_calls": stats_calls,
                     "stats_requests_scraped": d_stats,
                     "p99_true_s": true_p99, "p99_estimated_s": est_p99,
                     "p99_tolerance_s": tol},
        "gate": {"overhead_pct": overhead_pct,
                 "overhead_bound_pct": overhead_bound_pct,
                 "counters_exact": counts_ok,
                 "p99_within_bucket": p99_ok},
    }
    with open(out_json, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)

    print("metric,value")
    print(f"overhead_pct,{overhead_pct:.2f}")
    print(f"events_per_s_enabled,{ev_per_s_on:.0f}")
    print(f"events_scraped,{d_events:.0f}/{sent}")
    print(f"p99_est_ms,{est_p99 * 1e3:.3f}")
    print(f"p99_true_ms,{true_p99 * 1e3:.3f}")
    return {"name": "fig18_obs",
            "us_per_call": t_on * 1e6 / max(len(evs), 1),
            "derived": f"overhead {overhead_pct:+.1f}% "
                       f"(bound {overhead_bound_pct:.0f}%); counters "
                       f"{'exact' if counts_ok else 'MISMATCH'}; p99 "
                       f"{'ok' if p99_ok else 'OFF'}",
            "passes_gate": bool(overhead_ok and counts_ok and p99_ok)}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=240)
    ap.add_argument("--n0", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeats", type=int, default=4)
    args = ap.parse_args()
    run(events=args.events, n0=args.n0, seed=args.seed, repeats=args.repeats)
