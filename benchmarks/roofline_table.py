"""Framework roofline table: aggregates the dry-run JSONs into the
EXPERIMENTS.md §Roofline markdown table."""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(results_dir: str):
    rows = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def fmt_table(rows, mesh: str = "single") -> str:
    hdr = ("| arch | shape | status | HBM/dev | compute_s | memory_s | "
           "collective_s | dominant | useful FLOPs |")
    sep = "|" + "---|" * 9
    out = [hdr, sep]
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if r.get("status") == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | skipped "
                       f"({r['reason'][:40]}) | – | – | – | – | – | – |")
            continue
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | ERROR | – | – | – | "
                       f"– | – | – |")
            continue
        ro = r["roofline"]
        mem = r["memory"]["hbm_per_device_bytes"] / 1e9
        ur = r.get("useful_flops_ratio")
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | {mem:.1f} GB | "
            f"{ro['compute_s']:.4f} | {ro['memory_s']:.4f} | "
            f"{ro['collective_s']:.4f} | {ro['dominant']} | "
            f"{ur:.2f} |" if ur else
            f"| {r['arch']} | {r['shape']} | ok | {mem:.1f} GB | "
            f"{ro['compute_s']:.4f} | {ro['memory_s']:.4f} | "
            f"{ro['collective_s']:.4f} | {ro['dominant']} | – |")
    return "\n".join(out)


def run(results_dir: str = "results/dryrun"):
    rows = load(results_dir)
    ok = sum(1 for r in rows if r.get("status") == "ok")
    sk = sum(1 for r in rows if r.get("status") == "skipped")
    err = sum(1 for r in rows if r.get("status") not in ("ok", "skipped"))
    print(fmt_table(rows, "single"))
    print(f"\n# cells: {ok} ok / {sk} skipped / {err} error")
    return {"name": "roofline_table", "us_per_call": 0.0,
            "derived": f"{ok} ok/{sk} skipped/{err} err", "ok": err == 0}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results/dryrun")
    args = ap.parse_args()
    run(args.results)
