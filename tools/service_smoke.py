"""CI smoke: boot the control-plane daemon, stream a churn trace, shut down.

Starts ``python -m repro.service.server`` as a real subprocess, streams a
50-event poisson-churn trace through :class:`repro.service.ServiceClient`,
asserts every query endpoint answers sensibly, forces a re-optimization and
a snapshot, scrapes ``GET /v1/metrics`` and checks the counters match what
was streamed (a fresh process, so absolute values are exact), and checks
the daemon exits cleanly on ``POST /v1/shutdown``.

    PYTHONPATH=src python tools/service_smoke.py [--events 50] [--n0 32]
    PYTHONPATH=src python tools/service_smoke.py --policy dgro-hier --n0 96

With ``--policy dgro-hier`` the daemon serves a hierarchical overlay:
the same endpoint contract is asserted, plus the hier gauges
(``repro_hier_clusters``, ``repro_hier_headring_diameter``) and the
per-level ``repro_hier_route_hops`` histogram must appear in the scrape.

Run under both ``JAX_PLATFORMS=cpu`` and the default platform in CI.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.dynamics.scenarios import poisson_churn  # noqa: E402
from repro.service import ServiceClient  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=50)
    ap.add_argument("--n0", type=int, default=32)
    ap.add_argument("--dist", default="bitnode")
    ap.add_argument("--policy", default="dgro",
                    help="overlay policy the daemon serves "
                         "(e.g. dgro, dgro-hier)")
    ap.add_argument("--timeout", type=float, default=120.0)
    args = ap.parse_args()
    hier = args.policy == "dgro-hier"

    # a trace with >= the requested number of events (rates scale with count)
    trace = poisson_churn(n0=args.n0, dist=args.dist, seed=1,
                          horizon=30_000.0,
                          join_rate=args.events / 2 / 30_000.0,
                          leave_rate=args.events / 2 / 30_000.0)
    events = sorted(trace.events, key=lambda e: e.time)[:args.events]
    assert len(events) >= min(args.events, 40), (
        f"trace only produced {len(events)} events")

    snapdir = tempfile.mkdtemp(prefix="dgro-service-smoke-")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.service",
         "--n0", str(args.n0), "--capacity", str(trace.capacity),
         "--dist", args.dist, "--policy", args.policy,
         "--port", "0", "--snapshot-dir", snapdir,
         "--reopt-every", "16", "--snapshot-every", "25"],
        stdout=subprocess.PIPE, text=True,
        env={**os.environ, "PYTHONPATH": "src"})
    try:
        line = proc.stdout.readline().strip()
        assert line.startswith("SERVING "), f"unexpected boot line: {line!r}"
        port = dict(kv.split("=") for kv in line.split()[1:])["port"]
        c = ServiceClient(f"http://127.0.0.1:{port}")

        health = c.wait_ready(timeout=args.timeout)
        assert health["status"] == "ok" and "v1" in health["api_versions"]

        d0 = c.diameter()
        assert d0["diameter"] > 0 and d0["n_live"] == args.n0

        for i in range(0, len(events), 10):
            res = c.post_events(events[i:i + 10])
            assert res["applied"] >= res["accepted"] > 0, res

        st = c.stats()
        assert st["events_ingested"] == len(events), st
        assert st["n_live"] >= 4
        assert st["distances_are"] in ("exact", "lower-bound")
        if hier:
            assert st["clusters"] > 0, st
            assert st["reorg"]["head_rebuilds"] >= 0, st

        nodes = c.adjacency()["nodes"]
        assert len(nodes) == st["n_live"]
        r = c.route(nodes[0], nodes[-1])
        assert r["reachable"] and r["distance"] > 0
        assert r["path"] is None or (r["path"][0] == nodes[0]
                                     and r["path"][-1] == nodes[-1])
        # enriched routing keys (shared repro.routing router)
        if r["path"] is not None:
            assert r["hops"] == len(r["path"]) - 1, r
            # served distance is exact or a lower bound -> stretch >= 1
            assert r["stretch"] >= 1 - 1e-5, r
            assert r["hop_bounds"] == [r["bound"]] * r["hops"], r
            if hier:
                levels = r["hops_by_level"]
                assert levels["local"] + levels["head"] == r["hops"], r
        else:
            assert r["hops"] is None and r["stretch"] is None, r

        c.reoptimize()
        snap = c.snapshot()
        assert snap["seq"] >= 1, snap
        d1 = c.diameter(exact=True)
        assert d1["exact"] and d1["diameter"] > 0

        # the observability scrape: a fresh daemon process, so counters are
        # absolute — ingested events must match what this script streamed
        scraped = c.metrics()
        assert (scraped["repro_service_events_ingested_total"][()]
                == len(events)), scraped["repro_service_events_ingested_total"]
        reqs = scraped.get("repro_http_requests_total", {})
        assert sum(reqs.values()) > 0, "no HTTP requests counted"
        post_key = (("endpoint", "events"), ("method", "POST"),
                    ("status", "200"))
        assert reqs[post_key] == (len(events) + 9) // 10, reqs
        assert scraped["repro_service_n_live"][()] == st["n_live"]
        # the shared routing instruments: exactly one /v1/route was served
        # (the hier engine additionally counts its internal walk under
        # policy="hier-latency", so hier scrapes carry two series)
        route_reqs = scraped["repro_route_requests_total"]
        assert sum(route_reqs.values()) == (2 if hier else 1), route_reqs
        if r["path"] is not None:
            key = (("outcome", "delivered"), ("policy", "latency"))
            assert route_reqs[key] == 1, route_reqs
            assert scraped["repro_route_hops_count"][()] == 1, scraped

        if hier:
            # the hierarchical instruments must land in the same scrape:
            # the cluster/head-ring gauges are bound to live engine state,
            # and the delivered route above observed per-level hops
            assert scraped["repro_hier_clusters"][()] == st["clusters"] > 0, \
                scraped.get("repro_hier_clusters")
            assert scraped["repro_hier_headring_diameter"][()] >= 0, scraped
            hier_hops = scraped["repro_hier_route_hops_count"]
            local_key = (("level", "local"),)
            assert hier_hops.get(local_key, 0) >= 1, hier_hops

        # the APSP engine instruments: the forced re-optimization scored
        # candidates through batcheval, so the per-phase evaluation spans
        # and the working-set gauge must have landed in the same scrape
        apsp_counts = scraped["repro_apsp_seconds_count"]
        assert sum(apsp_counts.values()) >= 1, apsp_counts
        assert scraped["repro_apsp_workingset_bytes"][()] > 0, scraped

        c.shutdown()
        rc = proc.wait(timeout=30)
        assert rc == 0, f"daemon exited {rc}"
        out = proc.stdout.read()
        assert "STOPPED" in out, out
        print(f"OK  service smoke: {len(events)} events streamed, "
              f"n_live={st['n_live']}, diameter={d1['diameter']:.1f}, "
              f"clean shutdown")
    finally:
        if proc.poll() is None:
            proc.kill()


if __name__ == "__main__":
    main()
