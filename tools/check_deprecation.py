"""CI check: the legacy tuple shims are GONE, and fail loudly with a pointer.

The deprecated facades over the ``repro.overlay`` API (protocols.chord /
rapid / perigee / with_replaced_rings, selection.adapt_overlay,
qlearning.dgro_topology) spent two PR cycles emitting DeprecationWarning and
are now removed.  Touching one must raise ``AttributeError`` whose message
names the ``overlay.build``-era replacement — a hard stop with directions,
not a silent AttributeError from a missing name.

    PYTHONPATH=src python tools/check_deprecation.py
"""
from __future__ import annotations

from repro.core import protocols, qlearning, selection

REMOVED = [
    (protocols, "chord"),
    (protocols, "rapid"),
    (protocols, "perigee"),
    (protocols, "with_replaced_rings"),
    (selection, "adapt_overlay"),
    (qlearning, "dgro_topology"),
]

# every removal message must point at the Overlay API
_POINTER = "overlay."


def check_removed(module, name: str) -> None:
    label = f"{module.__name__}.{name}"
    try:
        getattr(module, name)
    except AttributeError as e:
        msg = str(e)
        assert "removed" in msg, (
            f"{label}: AttributeError should say the name was removed, "
            f"got: {msg}")
        assert _POINTER in msg, (
            f"{label}: AttributeError must point at the overlay API "
            f"replacement, got: {msg}")
        print(f"OK  {label}: gone -> {msg[:84]}...")
        return
    raise AssertionError(f"{label} is still importable; the shim should "
                         f"have been removed")


def check_survivors() -> None:
    # the non-deprecated names stayed behind
    import numpy as np

    from repro.core.diameter import INF

    w = np.array([[0.0, 1.0], [1.0, 0.0]])
    adj = np.array([[0.0, 1.0], [1.0, 0.0]])
    deg = protocols.node_degrees(np.where(adj > 0, adj, INF))
    assert list(deg) == [1, 1], deg
    assert callable(selection.adapt)
    assert callable(qlearning.dgro_overlay)
    print("OK  survivors: node_degrees / selection.adapt / dgro_overlay")


def main():
    for module, name in REMOVED:
        check_removed(module, name)
    check_survivors()
    print("all legacy shims removed; AttributeError points at overlay API")


if __name__ == "__main__":
    main()
