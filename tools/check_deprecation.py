"""CI check: legacy tuple shims emit DeprecationWarning exactly once.

Each deprecated facade over the ``repro.overlay`` API (protocols.chord /
rapid / perigee / with_replaced_rings, selection.adapt_overlay,
qlearning.dgro_topology) must warn on first use and stay silent on repeated
use — one actionable nudge per process, no log spam in tight loops.

    PYTHONPATH=src python tools/check_deprecation.py
"""
from __future__ import annotations

import warnings

import numpy as np

from repro.core import protocols, selection
from repro.core.topology import make_latency


def check(label, fn):
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")     # count raw emissions, no dedup
        fn()
        fn()
    dep = [r for r in rec if issubclass(r.category, DeprecationWarning)]
    assert len(dep) == 1, (
        f"{label}: expected exactly 1 DeprecationWarning over two calls, "
        f"got {len(dep)}: {[str(d.message) for d in dep]}")
    assert "deprecated" in str(dep[0].message), dep[0].message
    print(f"OK  {label}: warned exactly once -> {str(dep[0].message)[:72]}...")


def main():
    w = make_latency("uniform", 16, seed=0)
    rng = np.random.default_rng(0)
    adj, rings = None, None

    def chord():
        nonlocal adj, rings
        adj, rings = protocols.chord(w, np.random.default_rng(0))

    check("protocols.chord", chord)
    check("protocols.rapid", lambda: protocols.rapid(w, rng, k=2))
    check("protocols.perigee", lambda: protocols.perigee(w, rng))
    check("protocols.with_replaced_rings",
          lambda: protocols.with_replaced_rings(
              w, np.asarray(adj), rings, [np.random.default_rng(1).permutation(16)]))
    check("selection.adapt_overlay",
          lambda: selection.adapt_overlay(w, adj, seed=0))

    # the DQN shim warns too (untrained params: the facade, not the policy,
    # is under test)
    import jax

    from repro.core.embedding import init_qparams
    from repro.core.qlearning import DQNConfig, dgro_topology

    cfg = DQNConfig(n=8, k_rings=1)
    params = init_qparams(jax.random.PRNGKey(0), cfg.p, cfg.h)
    check("qlearning.dgro_topology",
          lambda: dgro_topology(params, cfg, w[:8, :8], n_starts=1))
    print("all legacy shims warn exactly once")


if __name__ == "__main__":
    main()
